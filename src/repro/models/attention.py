"""Attention: GQA projections + flash-style chunked attention.

Two regimes, mirroring the paper's kernel split (§4.2):

- ``flash_attention`` (train/prefill): block-chunked online-softmax attention
  implemented as a scan over a STATIC (q-chunk, kv-chunk) pair list — only
  causally/window-reachable blocks are enumerated, so HLO FLOPs equal the true
  triangular/banded cost (no 2× causal waste). Custom VJP recomputes blocks in
  the backward pass (FlashAttention-2 style) instead of saving (S×S) residuals.

- ``decode_attention`` (serve): one query against the contiguous KV cache,
  masked softmax. Under sequence-sharded KV rules the softmax reductions
  become the LSE-merge collectives (the §3.1 "add attention nodes" scaling).

The Pallas TPU kernels in ``repro.kernels.flash_decode`` implement the decode
path for real hardware; this module is the mathematically identical jnp form
used for CPU dry-runs (DESIGN.md §10).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.combine import combine_partial_stats
from repro.models import common
from repro.models.common import scan_unroll
from repro.models.sharding import ShardingCtx

NEG_INF = -1e30


def q_chunk_for(S: int) -> int:
    """Block size for banded flash: ≥512, ≤S/16 blocks per axis — bounds the
    static pair list (compile size) while keeping VMEM-friendly tiles."""
    return max(512, S // 16)


# ---------------------------------------------------------------------------
# Static pair list for banded block attention
# ---------------------------------------------------------------------------

def band_pairs(n_q: int, n_kv: int, q_chunk: int, kv_chunk: int,
               causal: bool, window: int, q_offset: int = 0):
    """Enumerate (i, j) blocks that contain at least one unmasked entry.
    ``q_offset``: absolute position of q block 0 (cross/self alignment)."""
    pairs = []
    for i in range(n_q):
        q_lo = q_offset + i * q_chunk
        q_hi = q_lo + q_chunk - 1
        for j in range(n_kv):
            k_lo, k_hi = j * kv_chunk, j * kv_chunk + kv_chunk - 1
            if causal and k_lo > q_hi:
                continue
            if window and k_hi < q_lo - window + 1:
                continue
            pairs.append((i, j))
    return pairs


# ---------------------------------------------------------------------------
# Block kernel (shared by fwd + bwd): returns scores-mask for a block
# ---------------------------------------------------------------------------

def _block_mask(i, j, q_chunk, kv_chunk, causal, window, q_offset):
    qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)
    kpos = j * kv_chunk + jnp.arange(kv_chunk)
    m = jnp.ones((q_chunk, kv_chunk), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window:
        m &= kpos[None, :] > (qpos[:, None] - window)
    return m


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0,
                    q_chunk: int = 512, kv_chunk: int = 512,
                    q_offset: int = 0, scale: Optional[float] = None,
                    kv_limit: int = 0) -> jax.Array:
    """q: (B, Sq, Hq, hd); k/v: (B, Sk, Hkv, hd); Hq % Hkv == 0. → (B,Sq,Hq,hd).
    Seq lens must be chunk multiples — use flash_attention_padded otherwise.
    kv_limit > 0 masks KV positions ≥ kv_limit (padding)."""
    o, _ = _flash_fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk,
                           q_offset, scale, kv_limit)
    return o


def flash_attention_padded(q, k, v, causal=True, window=0, q_chunk=512,
                           kv_chunk=512, q_offset=0, scale=None):
    """Pads Sq/Sk up to chunk multiples (masked), slices the result back."""
    B, Sq = q.shape[:2]
    Sk = k.shape[1]
    qc, kc = min(q_chunk, Sq), min(kv_chunk, Sk)
    Sq_p = -(-Sq // qc) * qc
    Sk_p = -(-Sk // kc) * kc
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    if Sk_p != Sk:
        k = jnp.pad(k, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    out = flash_attention(q, k, v, causal, window, qc, kc, q_offset, scale,
                          Sk if Sk_p != Sk else 0)
    return out[:, :Sq]


def _prep(q, k, q_chunk, kv_chunk, scale):
    B, Sq, Hq, hd = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Sk)
    # pad S to chunk multiples is the caller's job; assert here
    assert Sq % qc == 0 and Sk % kc == 0, (Sq, qc, Sk, kc)
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    return B, Sq, Sk, Hq, Hkv, G, hd, qc, kc, sc


def _flash_fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk, q_offset, scale, kv_limit=0):
    B, Sq, Sk, Hq, Hkv, G, hd, qc, kc, sc = _prep(q, k, q_chunk, kv_chunk, scale)
    pairs = band_pairs(Sq // qc, Sk // kc, qc, kc, causal, window, q_offset)
    ii = jnp.array([p[0] for p in pairs], jnp.int32)
    jj = jnp.array([p[1] for p in pairs], jnp.int32)

    qg = q.reshape(B, Sq, Hkv, G, hd)
    o = jnp.zeros((B, Sq, Hkv, G, hd), jnp.float32)
    m = jnp.full((B, Sq, Hkv, G), NEG_INF, jnp.float32)
    l = jnp.zeros((B, Sq, Hkv, G), jnp.float32)

    def body(carry, ij):
        o, m, l = carry
        i, j = ij
        qi = jax.lax.dynamic_slice_in_dim(qg, i * qc, qc, axis=1)
        kj = jax.lax.dynamic_slice_in_dim(k, j * kc, kc, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * kc, kc, axis=1)
        s = jnp.einsum("bqkgh,btkh->bkgqt", qi, kj,
                       preferred_element_type=jnp.float32) * sc  # (B,Hkv,G,qc,kc)
        mask = _block_mask_dyn(i, j, qc, kc, causal, window, q_offset, kv_limit)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        mi = jax.lax.dynamic_slice_in_dim(m, i * qc, qc, 1)    # (B,qc,Hkv,G)
        li = jax.lax.dynamic_slice_in_dim(l, i * qc, qc, 1)
        oi = jax.lax.dynamic_slice_in_dim(o, i * qc, qc, 1)
        m_blk = jnp.max(s, axis=-1).transpose(0, 3, 1, 2)      # (B,qc,Hkv,G)
        m_new = jnp.maximum(mi, m_blk)
        p = jnp.exp(s - m_new.transpose(0, 2, 3, 1)[..., None])
        corr = jnp.exp(mi - m_new)
        l_new = li * corr + jnp.sum(p, -1).transpose(0, 3, 1, 2)
        pv = jnp.einsum("bkgqt,btkh->bqkgh", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        o_new = oi * corr[..., None] + pv
        o = jax.lax.dynamic_update_slice_in_dim(o, o_new, i * qc, 1)
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, i * qc, 1)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_new, i * qc, 1)
        return (o, m, l), None

    (o, m, l), _ = jax.lax.scan(body, (o, m, l), (ii, jj), unroll=scan_unroll())
    l_safe = jnp.maximum(l, 1e-30)
    out = (o / l_safe[..., None]).reshape(B, Sq, Hq, hd).astype(q.dtype)
    lse = m + jnp.log(l_safe)                                  # (B,Sq,Hkv,G)
    return out, lse


def _block_mask_dyn(i, j, qc, kc, causal, window, q_offset, kv_limit=0):
    qpos = q_offset + i * qc + jnp.arange(qc)
    kpos = j * kc + jnp.arange(kc)
    m = jnp.ones((qc, kc), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window:
        m &= kpos[None, :] > (qpos[:, None] - window)
    if kv_limit:
        m &= kpos[None, :] < kv_limit
    return m


def _flash_fwd(q, k, v, causal, window, q_chunk, kv_chunk, q_offset, scale,
               kv_limit):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_chunk, kv_chunk,
                               q_offset, scale, kv_limit)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_chunk, kv_chunk, q_offset, scale, kv_limit,
               res, do):
    q, k, v, out, lse = res
    B, Sq, Sk, Hq, Hkv, G, hd, qc, kc, sc = _prep(q, k, q_chunk, kv_chunk, scale)
    pairs = band_pairs(Sq // qc, Sk // kc, qc, kc, causal, window, q_offset)
    ii = jnp.array([p[0] for p in pairs], jnp.int32)
    jj = jnp.array([p[1] for p in pairs], jnp.int32)

    qg = q.reshape(B, Sq, Hkv, G, hd).astype(jnp.float32)
    og = out.reshape(B, Sq, Hkv, G, hd).astype(jnp.float32)
    dog = do.reshape(B, Sq, Hkv, G, hd).astype(jnp.float32)
    D = jnp.sum(og * dog, axis=-1)                             # (B,Sq,Hkv,G)

    dq = jnp.zeros_like(qg)
    dk = jnp.zeros((B, Sk, Hkv, hd), jnp.float32)
    dv = jnp.zeros((B, Sk, Hkv, hd), jnp.float32)

    def body(carry, ij):
        dq, dk, dv = carry
        i, j = ij
        qi = jax.lax.dynamic_slice_in_dim(qg, i * qc, qc, 1)
        kj = jax.lax.dynamic_slice_in_dim(k, j * kc, kc, 1).astype(jnp.float32)
        vj = jax.lax.dynamic_slice_in_dim(v, j * kc, kc, 1).astype(jnp.float32)
        lse_i = jax.lax.dynamic_slice_in_dim(lse, i * qc, qc, 1)
        Di = jax.lax.dynamic_slice_in_dim(D, i * qc, qc, 1)
        doi = jax.lax.dynamic_slice_in_dim(dog, i * qc, qc, 1)
        s = jnp.einsum("bqkgh,btkh->bkgqt", qi, kj) * sc
        mask = _block_mask_dyn(i, j, qc, kc, causal, window, q_offset, kv_limit)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse_i.transpose(0, 2, 3, 1)[..., None])   # (B,Hkv,G,qc,kc)
        dvj = jnp.einsum("bkgqt,bqkgh->btkh", p, doi)
        dp = jnp.einsum("bqkgh,btkh->bkgqt", doi, vj)
        ds = p * (dp - Di.transpose(0, 2, 3, 1)[..., None]) * sc
        dqi = jnp.einsum("bkgqt,btkh->bqkgh", ds, kj)
        dkj = jnp.einsum("bkgqt,bqkgh->btkh", ds, qi)
        dq = jax.lax.dynamic_update_slice_in_dim(
            dq, jax.lax.dynamic_slice_in_dim(dq, i * qc, qc, 1) + dqi, i * qc, 1)
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, jax.lax.dynamic_slice_in_dim(dk, j * kc, kc, 1) + dkj, j * kc, 1)
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, jax.lax.dynamic_slice_in_dim(dv, j * kc, kc, 1) + dvj, j * kc, 1)
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(body, (dq, dk, dv), (ii, jj), unroll=scan_unroll())
    return (dq.reshape(B, Sq, Hq, hd).astype(q.dtype),
            dk.astype(k.dtype), dv.astype(v.dtype))


flash_attention.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# Decode attention (one query position against the KV cache)
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: jax.Array, ctx: ShardingCtx,
                     scale: Optional[float] = None) -> jax.Array:
    """q: (B, Hq, hd); k/v: (B, n_kv, S, hd); mask: (S,) or (B,S) bool.

    Plain masked softmax; when the rules shard S ("kv_seq"→data) the compiler
    turns the max/sum reductions into the distributed-flash LSE merge.
    """
    B, Hq, hd = q.shape
    n_kv = k.shape[1]
    G = Hq // n_kv
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, n_kv, G, hd)
    # bf16 operands, f32 accumulation — the MXU path; no materialized upcast
    s = jnp.einsum("bkgh,bksh->bkgs", qg, k,
                   preferred_element_type=jnp.float32) * sc
    s = ctx.ann(s, "batch", "kv_heads", None, "kv_seq")
    if mask.ndim == 1:
        mask = mask[None]
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgs,bksh->bkgh",
                   (p / jnp.maximum(l, 1e-30)).astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunk-prefill attention (C queries against the KV cache)
#
# The chunked-prefill lane (DESIGN.md §7) runs a fixed (1, C) program per
# prompt chunk: the chunk's C queries attend the full cache prefix the chunk
# just extended. The chunk offset is a TRACED scalar, so the static band-pair
# enumeration of ``flash_attention`` does not apply — this is the multi-query
# generalization of ``decode_attention`` (plain masked softmax over the cache
# extent), sharing its sharding annotations and its -inf/underflow masking
# semantics so bucketed and full-extent reads stay bit-identical.
# ---------------------------------------------------------------------------

def chunk_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    mask: jax.Array, ctx: ShardingCtx,
                    scale: Optional[float] = None) -> jax.Array:
    """q: (B, C, Hq, hd); k/v: (B, n_kv, S, hd); mask: (C, S) or (B, C, S)
    bool. → (B, C, Hq, hd). ``decode_attention`` is the C == 1 special case
    (modulo the query axis layout)."""
    B, C, Hq, hd = q.shape
    n_kv = k.shape[1]
    G = Hq // n_kv
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, C, n_kv, G, hd)
    s = jnp.einsum("bqkgh,bksh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * sc  # (B,n_kv,G,C,S)
    s = ctx.ann(s, "batch", "kv_heads", None, None, "kv_seq")
    if mask.ndim == 2:
        mask = mask[None]
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgqs,bksh->bqkgh",
                   (p / jnp.maximum(l, 1e-30)).astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, C, Hq, hd).astype(q.dtype)


def chunk_attention_tiered(q: jax.Array, k_hot: jax.Array, v_hot: jax.Array,
                           k_cold: jax.Array, v_cold: jax.Array,
                           hot_mask: jax.Array, mask: jax.Array,
                           ctx: ShardingCtx,
                           scale: Optional[float] = None) -> jax.Array:
    """``chunk_attention`` over a TIERED cache image: key position j of
    query i resolves to the exact hot value when ``hot_mask[b, i, j]`` and
    to the quantize-roundtrip cold value otherwise. The demotion boundary is
    per QUERY (it advances with each query's own count), so unlike the
    decode path the hot/cold select cannot be folded into one pre-selected
    (B,n_kv,S,hd) image — instead both tiers are scored and the (C,S)
    selection happens on the score/weight planes. Each (i, j) entry of the
    softmax sees exactly one tier, so the result equals ``chunk_attention``
    run on the per-query where-selected image.

    q: (B,C,Hq,hd); k/v tiers: (B,n_kv,S,hd) in compute dtype (cold already
    dequantized); hot_mask: (B,C,S) bool; mask: (C,S) or (B,C,S) bool."""
    B, C, Hq, hd = q.shape
    n_kv = k_hot.shape[1]
    G = Hq // n_kv
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, C, n_kv, G, hd)
    s_hot = jnp.einsum("bqkgh,bksh->bkgqs", qg, k_hot,
                       preferred_element_type=jnp.float32) * sc
    s_cold = jnp.einsum("bqkgh,bksh->bkgqs", qg, k_cold,
                        preferred_element_type=jnp.float32) * sc
    hm = hot_mask[:, None, None]                         # (B,1,1,C,S)
    s = jnp.where(hm, s_hot, s_cold)                     # (B,n_kv,G,C,S)
    s = ctx.ann(s, "batch", "kv_heads", None, None, "kv_seq")
    if mask.ndim == 2:
        mask = mask[None]
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    w = (p / jnp.maximum(l, 1e-30))
    zero = jnp.zeros((), w.dtype)
    o = jnp.einsum("bkgqs,bksh->bqkgh",
                   jnp.where(hm, w, zero).astype(v_hot.dtype), v_hot,
                   preferred_element_type=jnp.float32) \
      + jnp.einsum("bkgqs,bksh->bqkgh",
                   jnp.where(hm, zero, w).astype(v_cold.dtype), v_cold,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, C, Hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Length-aware (chunk-bucketed) decode attention
#
# A freshly admitted request sits at position ~prompt_len while the cache is
# sized for prompt_len + slack: scanning the full padded extent every step
# wastes bandwidth exactly where the paper says coordination/cache-path cost
# dominates decode (§5). The bucketed variant slices the KV to the smallest
# chunk multiple covering every live cursor — the bucket is a STATIC python
# int, so each bucket is its own compiled program (the serving engine fixes
# the bucket set at prepare time and picks per macro-step on the host).
# ---------------------------------------------------------------------------

def kv_buckets(s_max: int, chunk: int, shards: int = 1) -> Tuple[int, ...]:
    """Static bucket set for a cache of extent ``s_max``: chunk multiples
    ``(chunk, 2*chunk, ...)`` with ``s_max`` always the last (full) bucket.
    ``chunk <= 0`` disables bucketing (single full-extent program).

    ``shards > 1`` (split-KV decode): every bucket must cut into ``shards``
    equal shard-local blocks, so the chunk stride is rounded UP to a shard
    multiple and ``s_max`` itself must divide — a coarser-but-divisible
    bucket never loses tokens, it only reads a slightly longer prefix."""
    if shards > 1 and s_max % shards:
        raise ValueError(
            f"KV extent {s_max} not divisible by shards={shards}")
    if chunk <= 0 or chunk >= s_max:
        return (s_max,)
    if shards > 1:
        chunk = -(-chunk // shards) * shards
        if chunk >= s_max:
            return (s_max,)
    return tuple(range(chunk, s_max, chunk)) + (s_max,)


def bucket_for(needed: int, buckets: Tuple[int, ...]) -> int:
    """Smallest bucket covering ``needed`` KV positions (falls back to the
    full extent — the engine guarantees needed <= s_max)."""
    for b in buckets:
        if b >= needed:
            return b
    return buckets[-1]


def decode_attention_bucketed(q: jax.Array, k: jax.Array, v: jax.Array,
                              mask: jax.Array, ctx: ShardingCtx,
                              kv_bucket: int = 0,
                              scale: Optional[float] = None) -> jax.Array:
    """``decode_attention`` over only the first ``kv_bucket`` KV positions
    (static slice). The caller must guarantee every attendable position is
    < kv_bucket — the mask cannot recover positions sliced away.
    ``kv_bucket`` of 0 or >= S is the identity (full extent).

    This is the bucketed form for callers holding DEQUANTIZED (B,n_kv,S,hd)
    tensors. The serving decode path slices one level lower instead —
    ``kv/cache.py::layer_read_bucket`` cuts the stored (possibly int8)
    buffers before dequantization — and then calls plain decode_attention.
    The two slices must keep identical semantics (first-``kv_bucket``
    prefix); test_macro_step.py pins both against the full-extent walk."""
    S = k.shape[2]
    if kv_bucket and kv_bucket < S:
        k = jax.lax.slice_in_dim(k, 0, kv_bucket, axis=2)
        v = jax.lax.slice_in_dim(v, 0, kv_bucket, axis=2)
        mask = jax.lax.slice_in_dim(mask, 0, kv_bucket, axis=mask.ndim - 1)
    return decode_attention(q, k, v, mask, ctx, scale)


# ---------------------------------------------------------------------------
# Split-KV flash decode (sequence-sharded bucketed read, DESIGN.md §3)
#
# Flash-decoding for the A domain: one slot's KV walk is cut into n_shards
# contiguous shard-local blocks; every shard computes its partial flash
# statistics (running max / normalizer / weighted accumulator) with purely
# shard-local reductions, and one LSE merge (kernels/flash_decode/combine.py)
# folds the shards. Under the ``seq_sharded_kv`` rules the "kv_shard" axis
# maps onto the A submesh, so the per-shard einsums stay device-local and
# only the tiny (o, m, l) triples cross devices in the combine — attention
# latency then scales with A-domain width independently of pipeline depth
# (the paper's §2.3 decoupling claim, now *within* a sequence).
# ---------------------------------------------------------------------------

def decode_attention_split(q: jax.Array, k: jax.Array, v: jax.Array,
                           mask: jax.Array, ctx: ShardingCtx,
                           scale: Optional[float] = None) -> jax.Array:
    """q: (B, Hq, hd); k/v SHARD-MAJOR (B, n_kv, n_shards, Sb, hd); mask:
    (B, n_shards*Sb) or (B, n_shards, Sb) bool → (B, Hq, hd).

    Shard s owns the contiguous absolute positions [s*Sb, (s+1)*Sb) of the
    (bucketed) cache prefix. Token-exact vs the sequential walk: a shard
    wholly past a slot's true length contributes exp(NEG_INF - m*) == 0
    weight against any live shard, and shard 0 always holds position 0 of
    a live slot, so the merge never sees an all-empty row that matters."""
    B, Hq, hd = q.shape
    n_kv, n, Sb = k.shape[1], k.shape[2], k.shape[3]
    G = Hq // n_kv
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(B, n_kv, G, hd)
    k = ctx.ann(k, "batch", "kv_heads", "kv_shard", "kv_seq", "head_dim")
    v = ctx.ann(v, "batch", "kv_heads", "kv_shard", "kv_seq", "head_dim")
    s = jnp.einsum("bkgh,bknsh->bkgns", qg, k,
                   preferred_element_type=jnp.float32) * sc  # (B,n_kv,G,n,Sb)
    s = ctx.ann(s, "batch", "kv_heads", None, "kv_shard", "kv_seq")
    if mask.ndim == 2:
        mask = mask.reshape(B, n, Sb)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    # per-shard partial flash statistics — reductions over Sb only (local)
    m = jnp.max(s, axis=-1)                                  # (B,n_kv,G,n)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgns,bknsh->bkgnh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)       # (B,n_kv,G,n,hd)
    o = ctx.ann(o, "batch", "kv_heads", None, "kv_shard", "head_dim")
    # cross-shard reduction: the LSE merge over the shard axis — on a live
    # A submesh this is the only place shards exchange data
    out = combine_partial_stats(o, m, l, axis=3)
    return out.reshape(B, Hq, hd).astype(q.dtype)


def decode_attention_split_bucketed(q: jax.Array, k: jax.Array, v: jax.Array,
                                    mask: jax.Array, ctx: ShardingCtx,
                                    n_shards: int, kv_bucket: int = 0,
                                    scale: Optional[float] = None) -> jax.Array:
    """Bucketed split-KV read for callers holding DEQUANTIZED 4-D KV: the
    same static bucket-prefix slice as ``decode_attention_bucketed``, then a
    contiguous reshape to shard-major and the split flash walk. The serving
    path slices/reshapes one level lower (``kv/cache.py::layer_read_shards``,
    pre-dequantization) with identical slice semantics."""
    S = k.shape[2]
    if kv_bucket and kv_bucket < S:
        k = jax.lax.slice_in_dim(k, 0, kv_bucket, axis=2)
        v = jax.lax.slice_in_dim(v, 0, kv_bucket, axis=2)
        mask = jax.lax.slice_in_dim(mask, 0, kv_bucket, axis=mask.ndim - 1)
    B, n_kv, Se, hd = k.shape
    if Se % n_shards:
        raise ValueError(
            f"KV extent {Se} not divisible by n_shards={n_shards}")
    Sb = Se // n_shards
    k = k.reshape(B, n_kv, n_shards, Sb, hd)
    v = v.reshape(B, n_kv, n_shards, Sb, hd)
    if mask.ndim == 1:
        mask = mask[None]
    return decode_attention_split(q, k, v, mask, ctx, scale)


# ---------------------------------------------------------------------------
# GQA projection parameter bundle
# ---------------------------------------------------------------------------

def make_attn_params(key, cfg, d_model: Optional[int] = None) -> dict:
    d = d_model or cfg.d_model
    dt = common.dtype_of(cfg)
    ks = jax.random.split(key, 6)
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": common.make_linear(ks[0], d, hq * hd, dt, bias=cfg.qkv_bias,
                                 int8=cfg.weight_int8),
        "wk": common.make_linear(ks[1], d, hkv * hd, dt, bias=cfg.qkv_bias,
                                 int8=cfg.weight_int8),
        "wv": common.make_linear(ks[2], d, hkv * hd, dt, bias=cfg.qkv_bias,
                                 int8=cfg.weight_int8),
        "wo": common.make_linear(ks[3], hq * hd, d, dt, int8=cfg.weight_int8),
    }
    if getattr(cfg, "qk_norm", False) or cfg.name.startswith("qwen3-moe"):
        p["q_norm"] = common.make_norm("rmsnorm", hd, dt)
        p["k_norm"] = common.make_norm("rmsnorm", hd, dt)
    return p


def qkv_project(p: dict, x: jax.Array, cfg, ctx: ShardingCtx,
                positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B,S,D) → q (B,S,Hq,hd), k/v (B,S,Hkv,hd) with RoPE applied.

    NOTE (paper §3.2 "head independence"): there is deliberately NO sharding
    annotation forcing materialization between this projection and attention —
    each head's Q/K/V stays on the shard that owns the head ("act_heads").
    """
    B, S, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = common.linear(p["wq"], x).reshape(B, S, hq, hd)
    k = common.linear(p["wk"], x).reshape(B, S, hkv, hd)
    v = common.linear(p["wv"], x).reshape(B, S, hkv, hd)
    if "q_norm" in p:
        q = common.apply_norm("rmsnorm", p["q_norm"], q, cfg.norm_eps)
        k = common.apply_norm("rmsnorm", p["k_norm"], k, cfg.norm_eps)
    if cfg.pos == "rope":
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
    q = ctx.ann(q, "batch", "seq", "act_heads", "head_dim")
    k = ctx.ann(k, "batch", "seq", "kv_heads", "head_dim")
    v = ctx.ann(v, "batch", "seq", "kv_heads", "head_dim")
    return q, k, v
