"""Logical-axis sharding rules (MaxText-style).

Every parameter/activation is annotated with *logical* axis names; an
ExecutionRules table maps logical names → mesh axes. The two execution models
of the paper differ ONLY by their rules table:

- ``OPERATOR_CENTRIC``: activations are forced fully-materialized (replicated)
  at every operator boundary — the compiler must insert an all-gather /
  all-reduce after each sharded op. This is the paper's "operator-centric"
  baseline (§2.4): synchronize + materialize between operators.

- ``SUB_OPERATOR``: activations stay head-/channel-sharded through the true
  dependency chain (QKV→RoPE→attention→O-partial) with a single
  reduce-scatter at each residual merge — the paper's dependency-driven
  execution (§3.2). Collectives happen only where semantics require them.

The rules engine degrades gracefully: if a logical dim is not divisible by
its mesh axis size, the annotation drops that axis (replication) — e.g. 4 KV
heads on a 16-way model axis.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ExecutionRules:
    """logical axis name → mesh axis name (or None = replicate)."""
    name: str
    rules: Dict[str, Optional[Tuple[str, ...]]]

    def mesh_axes(self, logical: Tuple[Optional[str], ...],
                  mesh: Mesh, shape: Tuple[int, ...]) -> P:
        """Translate logical names into a PartitionSpec, dropping axes that
        don't divide the corresponding dim (→ replicated)."""
        spec = []
        used = set()
        for dim, name in zip(shape, logical):
            entry = self.rules.get(name) if name else None
            if entry is None:
                spec.append(None)
                continue
            axes = tuple(a for a in entry
                         if a not in used and a in mesh.shape)
            total = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
            if axes and total > 0 and dim % total == 0:
                spec.append(axes if len(axes) > 1 else axes[0])
                used.update(axes)
            else:
                spec.append(None)
        return P(*spec)


# --- the canonical logical axis vocabulary ---------------------------------
# batch       : request batch
# seq         : sequence positions (activations)
# kv_seq      : KV-cache sequence positions
# embed       : d_model channels
# embed_shard : d_model channels in the scattered (post reduce-scatter) state
# heads       : query heads
# kv_heads    : KV heads
# head_dim    : per-head channels
# mlp         : FFN hidden channels
# vocab       : vocabulary
# experts     : MoE experts
# layers      : stacked layer dim (scan)
# stages      : pipeline stage dim (PP over pods)
# lru         : RG-LRU width channels
# ssm_heads   : mamba2 heads
# state       : ssm state channels
# conv        : conv taps
# frames      : encoder frames (audio/vision stub)

def _common(pod_data: Tuple[str, ...]) -> Dict[str, Optional[Tuple[str, ...]]]:
    return {
        "batch": pod_data,
        "seq": None,
        "kv_seq": None,
        "kv_shard": None,         # split-KV shard axis; → ("model",) only
                                  # under seq_sharded_kv (A-domain split)
        "embed": None,
        "heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": None,
        "mlp": ("model",),
        "mlp_shard": ("data",),   # expert-FFN cols: EP(model) × data — a 235B
                                  # MoE must not replicate experts across rows
        "embed_w": None,          # weight-matrix embed dim; → ("data",) under
                                  # FSDP (training) so params+opt fully shard
        "vocab": ("model",),
        "experts": ("model",),
        "layers": None,
        "stages": ("pod",),
        "lru": ("model",),
        "ssm_heads": ("model",),
        "state": None,
        "conv": None,
        "frames": None,
    }


def operator_centric(pod_is_dp: bool = True) -> ExecutionRules:
    """Operator-boundary materialization: activations replicate on the model
    axis between ops (embed → None) — all partial results are synchronized
    and materialized (the §2.4 baseline)."""
    rules = _common(("pod", "data") if pod_is_dp else ("data",))
    rules["embed_shard"] = None          # residual stream fully materialized
    rules["act_heads"] = None            # per-head activations gathered
    return ExecutionRules("operator_centric", rules)


def sub_operator(pod_is_dp: bool = True) -> ExecutionRules:
    """Dependency-driven: per-head activations stay on the owning shard,
    residual stream lives reduce-scattered over the model axis between
    blocks (one bounded-fan-in ring reduction per true dependency)."""
    rules = _common(("pod", "data") if pod_is_dp else ("data",))
    rules["embed_shard"] = ("model",)    # residual stream scattered (SP-style)
    rules["act_heads"] = ("model",)      # per-head activations stay local
    return ExecutionRules("sub_operator", rules)


def fsdp(base: ExecutionRules) -> ExecutionRules:
    """Training variant: weight matrices fully sharded (ZeRO-3/FSDP) — the
    non-TP weight dim and embedding rows spread over the data axis; GSPMD
    inserts the per-layer all-gather / grad reduce-scatter. Required to fit
    params + f32 AdamW moments for the ≥70B archs (76B: 0.76 TB params+opt
    per data row if replicated — does not fit 16 GB chips)."""
    rules = dict(base.rules)
    rules["embed_w"] = ("data",)
    return ExecutionRules(base.name + "+fsdp", rules)


def seq_sharded_kv(base: ExecutionRules) -> ExecutionRules:
    """Beyond-paper variant of §3.1's "attach more attention nodes" axis:
    the KV *sequence* is sharded over the model axis (distributed flash
    decode; softmax max/sum reductions become the LSE-merge collectives).

    Removes the KV-head/attention replication that head-sharding forces on
    archs whose n_kv_heads (or n_heads) don't divide the TP width — e.g.
    qwen2's 2 KV heads or phi3-medium's 40 q heads on a 16-way axis. Batch
    stays on data; KV context splits 16-way on model.

    Split-KV flash decode shards *within* a slot on the same axis: the
    "kv_shard" dim (the n_shards blocks of one slot's walk) takes the model
    axis, and a "kv_seq" annotation on the same tensor then drops to
    replicated (the ``used``-set rule) — each device owns whole shard-local
    blocks, computes their partial flash statistics locally, and only the
    (o, m, l) triples cross devices in the LSE merge."""
    rules = dict(base.rules)
    rules["kv_seq"] = ("model",)
    rules["kv_shard"] = ("model",)
    rules["kv_heads"] = None
    rules["act_heads"] = None          # q gathers (tiny at decode: B×D)
    return ExecutionRules(base.name + "+seqkv", rules)


# ---------------------------------------------------------------------------
# Annotation helpers
# ---------------------------------------------------------------------------
class ShardingCtx:
    """Carries (mesh, rules) through model code; ``ann`` constrains an
    intermediate activation, ``spec`` builds parameter PartitionSpecs."""

    def __init__(self, mesh: Optional[Mesh], rules: ExecutionRules):
        self.mesh = mesh
        self.rules = rules

    def spec(self, logical: Tuple[Optional[str], ...], shape: Tuple[int, ...]) -> P:
        if self.mesh is None:
            return P()
        return self.rules.mesh_axes(logical, self.mesh, shape)

    def sharding(self, logical, shape) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical, shape))

    def ann(self, x: jax.Array, *logical: Optional[str]) -> jax.Array:
        """with_sharding_constraint under the rules; no-op without a mesh."""
        if self.mesh is None or self.mesh.empty:
            return x
        spec = self.spec(tuple(logical), x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


NULL_CTX = ShardingCtx(None, operator_centric())
