from repro.data.synthetic import SyntheticLMData, make_batch_specs  # noqa: F401
