"""Deterministic synthetic LM data pipeline.

Generates a stationary Markov-ish token stream (learnable structure so train
loss actually falls), deterministic in (seed, step) — so a restarted/elastic
job resumes mid-epoch with byte-identical batches (checkpoint stores only the
step counter). Batches are produced host-side and sharded by the caller's
in_shardings; an async double-buffer hides generation latency.
"""
from __future__ import annotations

import threading
from queue import Queue
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeConfig


def make_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    from repro.models.registry import build_model
    return build_model(cfg).input_specs(shape)


class SyntheticLMData:
    """tokens[t+1] ~ affine-permutation of tokens[t] + noise → learnable."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0, noise: float = 0.1, prefetch: int = 2):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.seed, self.noise = seed, noise
        self._q: Queue = Queue(maxsize=prefetch)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- deterministic batch construction --------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        v = self.cfg.vocab_size
        rng = np.random.Generator(np.random.Philox(key=self.seed + (step << 20)))
        a = 31337 % v or 1
        b = 917 % v
        toks = np.empty((self.batch, self.seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, size=self.batch)
        noise_mask = rng.random((self.batch, self.seq)) < self.noise
        noise_tok = rng.integers(0, v, size=(self.batch, self.seq))
        for t in range(self.seq):
            nxt = (toks[:, t].astype(np.int64) * a + b) % v
            toks[:, t + 1] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.family == "audio":
            out["frames"] = rng.standard_normal(
                (self.batch, self.cfg.encoder.n_frames, self.cfg.d_model),
                dtype=np.float32)
        if self.cfg.family == "vlm":
            out["vision_embeds"] = rng.standard_normal(
                (self.batch, self.cfg.n_vision_tokens, self.cfg.d_model),
                dtype=np.float32)
        return out

    # -- async prefetch ---------------------------------------------------
    def start(self, from_step: int = 0):
        self._stop.clear()

        def worker():
            step = from_step
            while not self._stop.is_set():
                self._q.put((step, self.batch_at(step)))
                step += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        return self

    def __iter__(self) -> Iterator:
        while True:
            yield self._q.get()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            while not self._q.empty():
                self._q.get_nowait()
