"""jit'd wrapper with platform dispatch for decode attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.combine import (combine_partial_stats,
                                                merge_partial_stats)
from repro.kernels.flash_decode.flash_decode import flash_decode_pallas
from repro.kernels.flash_decode.ref import (flash_decode_ref,
                                            flash_decode_ref_partial)

__all__ = ["flash_decode", "flash_decode_partial", "combine_partial_stats",
           "merge_partial_stats"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "block_s"))
def flash_decode(q, k, v, mask, k_scale=None, v_scale=None, *,
                 use_pallas: bool = None, interpret: bool = False,
                 block_s: int = 512, kv_limit=None) -> jax.Array:
    """Decode attention. q: (B,Hq,hd); k/v: (B,n_kv,S,hd); mask: (B,S).

    ``kv_limit`` (optional, traced int32): max live KV extent — the Pallas
    kernel skips tiles wholly past it (length-aware walk); the jnp reference
    applies it as a mask cut so both paths agree numerically."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return flash_decode_pallas(q, k, v, k_scale, v_scale, mask,
                                   block_s=block_s,
                                   interpret=interpret or not _on_tpu(),
                                   kv_limit=kv_limit)
    if k_scale is not None:
        k = k.astype(jnp.float32) * k_scale
        v = v.astype(jnp.float32) * v_scale
    return flash_decode_ref(q, k, v, mask, kv_limit=kv_limit)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "block_s"))
def flash_decode_partial(q, k, v, mask, k_scale=None, v_scale=None, *,
                         use_pallas: bool = None, interpret: bool = False,
                         block_s: int = 512, kv_limit=None):
    """Split-KV shard-local decode attention: same dispatch as
    ``flash_decode`` but returns the UN-normalized flash statistics
    ``(o (B,Hq,hd), m (B,Hq), l (B,Hq))`` f32 for a cross-shard
    ``combine_partial_stats`` merge. ``kv_limit`` here is the SHARD-LOCAL
    live extent; a shard with ``kv_limit <= 0`` yields the merge identity
    ``(0, NEG_INF, 0)`` on both paths."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return flash_decode_pallas(q, k, v, k_scale, v_scale, mask,
                                   block_s=block_s,
                                   interpret=interpret or not _on_tpu(),
                                   kv_limit=kv_limit, partial_stats=True)
    if k_scale is not None:
        k = k.astype(jnp.float32) * k_scale
        v = v.astype(jnp.float32) * v_scale
    return flash_decode_ref_partial(q, k, v, mask, kv_limit=kv_limit)
