"""Flash-style decode attention Pallas TPU kernel.

TPU adaptation of the paper's attention kernel (§4.2): "KV-cache blocks are
processed in a tiled fashion, computing attention scores and value aggregation
without materializing large intermediate matrices ... we rely on LLC streaming
for KV blocks while maintaining query vectors in private cache." Here:
- KV tiles stream HBM→VMEM via BlockSpec, touched exactly once;
- the (G, hd) query group block is VMEM-pinned across the S grid walk;
- online softmax (running max / normalizer) in the revisited output block —
  no (H, S) score matrix is ever materialized.

Grid: (B, n_kv, n_S) — S innermost; per-(batch, kv-head) accumulators
(o, m, l) are carried as revisited output blocks (interpret-mode friendly).
GQA folds the head group G = Hq // n_kv into the query block.
Supports INT8 KV via per-position scales (paper runs fully-INT8 KV).

Length-aware tile skipping: ``kv_limit`` (a traced (1,1) int32 operand — NO
recompile as cursors advance) is the max live KV extent; every tile whose
first position is past it skips the whole score/PV body under ``pl.when``.
In a serving cache padded to prompt_len + slack the live prefix is usually a
small fraction of S_max, so most tiles retire after one scalar compare —
the kernel-level twin of the engine's chunk-bucketed program selection.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, ks_ref, vs_ref, mask_ref, lim_ref,
            o_ref, m_ref, l_ref, *, n_s: int, block_s: int, scale: float,
            quantized: bool, partial_stats: bool = False):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # tile early-out: positions [s_idx*bs, ...) wholly past every live
    # cursor contribute nothing — skip scores AND value aggregation
    @pl.when(s_idx * block_s < lim_ref[0, 0])
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (S_blk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        if quantized:
            k = k * ks_ref[0, 0].astype(jnp.float32)     # (S_blk,1) scales
            v = v * vs_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = mask_ref[0]                               # (S_blk,)
        s = jnp.where(mask[None, :], s, NEG_INF)

        m_prev = m_ref[0, 0]                             # (G, 1)
        m_blk = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_blk)
        p = jnp.exp(s - m_new)                           # (G, S_blk)
        corr = jnp.exp(m_prev - m_new)                   # (G, 1)
        l_ref[0, 0] = l_ref[0, 0] * corr + jnp.sum(p, axis=1, keepdims=True)
        o_ref[0, 0] = (o_ref[0, 0] * corr
                       + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                             preferred_element_type=jnp.float32))
        m_ref[0, 0] = m_new

    # split-KV partial mode defers normalization to the cross-shard combine
    # (combine.py): the raw (o, m, l) triple IS the kernel's output
    if not partial_stats:
        @pl.when(s_idx == n_s - 1)
        def _norm():
            o_ref[0, 0] /= jnp.maximum(l_ref[0, 0], 1e-30)


@functools.partial(jax.jit,
                   static_argnames=("block_s", "scale", "interpret",
                                    "partial_stats"))
def flash_decode_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                        k_scale, v_scale, mask: jax.Array, *,
                        block_s: int = 512, scale: float = None,
                        interpret: bool = False,
                        kv_limit=None, partial_stats: bool = False):
    """q: (B,Hq,hd); k/v: (B,n_kv,S,hd) (int8 ⇒ scales (B,n_kv,S,1) f32,
    else pass None); mask: (B,S) bool → (B,Hq,hd) f32.

    ``kv_limit``: optional scalar/0-d/(1,1) int32 — max live KV extent over
    the batch (e.g. ``max(positions) + 1`` after the append). Tiles wholly
    past it are skipped. TRACED, not static: callers pass a fresh value
    every step with zero recompilation. The caller must guarantee the mask
    is already False at positions >= kv_limit — the limit is a fast-path
    hint, never a semantic mask.

    ``partial_stats`` (static): split-KV mode — skip the final
    normalization and return the raw ``(o, m, l)`` flash statistics as
    ``((B,Hq,hd), (B,Hq), (B,Hq))`` f32 for a cross-shard
    ``combine_partial_stats`` merge. A call whose ``kv_limit`` skips every
    tile returns the exact merge identity ``(0, NEG_INF, 0)``."""
    B, Hq, hd = q.shape
    _, n_kv, S, _ = k.shape
    G = Hq // n_kv
    bs = min(block_s, S)
    assert S % bs == 0, (S, bs)
    n_s = S // bs
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    quantized = k_scale is not None
    qg = q.reshape(B, n_kv, G, hd)
    if not quantized:                 # feed dummies so the arity is static
        k_scale = jnp.ones((B, n_kv, 1, 1), jnp.float32)
        v_scale = jnp.ones((B, n_kv, 1, 1), jnp.float32)
    ss = k_scale.shape[2]
    if kv_limit is None:
        kv_limit = jnp.full((1, 1), S, jnp.int32)
    else:
        kv_limit = jnp.asarray(kv_limit, jnp.int32).reshape(1, 1)

    grid = (B, n_kv, n_s)
    o, m, l = pl.pallas_call(
        functools.partial(_kernel, n_s=n_s, block_s=bs, scale=sc,
                          quantized=quantized, partial_stats=partial_stats),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs if quantized else ss, 1),
                         (lambda b, h, s: (b, h, s, 0)) if quantized
                         else (lambda b, h, s: (b, h, 0, 0))),
            pl.BlockSpec((1, 1, bs if quantized else ss, 1),
                         (lambda b, h, s: (b, h, s, 0)) if quantized
                         else (lambda b, h, s: (b, h, 0, 0))),
            pl.BlockSpec((1, bs), lambda b, h, s: (b, s)),
            pl.BlockSpec((1, 1), lambda b, h, s: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, s: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n_kv, G, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, n_kv, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, n_kv, G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, k_scale, v_scale, mask, kv_limit)
    if partial_stats:
        return (o.reshape(B, Hq, hd), m.reshape(B, Hq), l.reshape(B, Hq))
    return o.reshape(B, Hq, hd)
