"""Pure-jnp oracle for decode attention (one query vs. contiguous KV)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: jax.Array, scale: float = None,
                     kv_limit=None) -> jax.Array:
    """q: (B,Hq,hd); k/v: (B,n_kv,S,hd); mask: (B,S) bool → (B,Hq,hd) f32.
    ``kv_limit`` folds into the mask (positions >= limit never attend) —
    the oracle form of the Pallas kernel's tile early-out."""
    B, Hq, hd = q.shape
    n_kv = k.shape[1]
    G = Hq // n_kv
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    if kv_limit is not None:
        lim = jnp.asarray(kv_limit, jnp.int32).reshape(())
        mask = mask & (jnp.arange(k.shape[2], dtype=jnp.int32)[None] < lim)
    qg = q.reshape(B, n_kv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bksh->bkgs", qg, k.astype(jnp.float32)) * sc
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksh->bkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, hd)
