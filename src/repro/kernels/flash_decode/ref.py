"""Pure-jnp oracle for decode attention (one query vs. contiguous KV)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     mask: jax.Array, scale: float = None,
                     kv_limit=None) -> jax.Array:
    """q: (B,Hq,hd); k/v: (B,n_kv,S,hd); mask: (B,S) bool → (B,Hq,hd) f32.
    ``kv_limit`` folds into the mask (positions >= limit never attend) —
    the oracle form of the Pallas kernel's tile early-out."""
    B, Hq, hd = q.shape
    n_kv = k.shape[1]
    G = Hq // n_kv
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    if kv_limit is not None:
        lim = jnp.asarray(kv_limit, jnp.int32).reshape(())
        mask = mask & (jnp.arange(k.shape[2], dtype=jnp.int32)[None] < lim)
    qg = q.reshape(B, n_kv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bksh->bkgs", qg, k.astype(jnp.float32)) * sc
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksh->bkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Hq, hd)


def flash_decode_ref_partial(q: jax.Array, k: jax.Array, v: jax.Array,
                             mask: jax.Array, scale: float = None,
                             kv_limit=None):
    """Un-normalized flash statistics over one KV shard — the oracle twin of
    ``flash_decode_pallas(..., partial_stats=True)``.

    Returns ``(o (B,Hq,hd), m (B,Hq), l (B,Hq))`` f32 for the cross-shard
    ``combine_partial_stats`` merge. A shard whose ``kv_limit <= 0`` (no
    live positions at all) is reported as the exact merge identity
    ``(0, NEG_INF, 0)``, matching the kernel whose tiles all early-out.
    Mask-empty rows inside a live shard follow the same uniform-weight
    convention as ``flash_decode_ref`` (their weight underflows to zero in
    the combine against any live shard)."""
    B, Hq, hd = q.shape
    n_kv = k.shape[1]
    G = Hq // n_kv
    S = k.shape[2]
    sc = scale if scale is not None else 1.0 / math.sqrt(hd)
    lim = None
    if kv_limit is not None:
        lim = jnp.asarray(kv_limit, jnp.int32).reshape(())
        mask = mask & (jnp.arange(S, dtype=jnp.int32)[None] < lim)
    qg = q.reshape(B, n_kv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bksh->bkgs", qg, k.astype(jnp.float32)) * sc
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                  # (B,n_kv,G)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgs,bksh->bkgh", p, v.astype(jnp.float32))
    if lim is not None:                  # fully-skipped shard -> identity
        empty = lim <= 0
        o = jnp.where(empty, 0.0, o)
        m = jnp.where(empty, NEG_INF, m)
        l = jnp.where(empty, 0.0, l)
    return (o.reshape(B, Hq, hd), m.reshape(B, Hq), l.reshape(B, Hq))
