"""Partial-softmax combine for split-KV flash decode (§4.2 + DESIGN.md §3).

Flash decoding splits one slot's KV walk along the sequence axis: every
shard runs the flash-decode kernel over its local KV slice and emits
UN-normalized statistics

    o_s  — weighted value accumulator  sum_j exp(score_j - m_s) * v_j
    m_s  — running max of masked scores inside the shard
    l_s  — normalizer                  sum_j exp(score_j - m_s)

``merge_partial_stats`` folds shard statistics with the standard LSE merge

    m* = max_s m_s;   a_s = exp(m_s - m*);   l* = sum_s l_s * a_s
    o* = sum_s o_s * a_s

and ``combine_partial_stats`` additionally normalizes ``o* / max(l*, eps)``
— exactly the deferred ``_norm`` step of the sequential kernel walk.

Conventions (shared with ``flash_decode.py``):
- the "no scores yet" sentinel is the FINITE ``NEG_INF = -1e30`` (never
  ``-inf`` — ``-inf - -inf`` would poison the merge with NaNs);
- a shard skipped entirely (``kv_limit``-empty) reports the exact merge
  identity ``(o=0, m=NEG_INF, l=0)``: its ``a_s`` underflows to 0 against
  any live shard, so appending empty shards is bit-stable (the combined
  output is bit-identical with or without them);
- all-empty input normalizes to 0 via the ``max(l*, eps)`` guard — the
  same answer the sequential kernel's ``_norm`` gives a dead row.

The merge is associative, so shards may be combined pairwise in any tree
shape (a cross-device ``psum``-style reduction on the A submesh, or one
flat reduction as here); statistics are always merged in float32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30
_EPS = 1e-30


def merge_partial_stats(o: jax.Array, m: jax.Array, l: jax.Array,
                        axis: int = 0):
    """Merge per-shard flash statistics along the shard axis.

    ``m``/``l`` have identical shapes; ``o`` carries one extra trailing
    head_dim. ``axis`` indexes the shard dimension of ``m`` (non-negative).
    Returns un-normalized ``(o*, m*, l*)`` with the shard axis reduced —
    itself a valid shard statistic, so merges compose into trees."""
    o = o.astype(jnp.float32)
    m = m.astype(jnp.float32)
    l = l.astype(jnp.float32)
    m_star = jnp.max(m, axis=axis, keepdims=True)
    alpha = jnp.exp(m - m_star)                      # <= 1, empty shards -> 0
    l_star = jnp.sum(l * alpha, axis=axis)
    o_star = jnp.sum(o * jnp.expand_dims(alpha, -1), axis=axis)
    return o_star, jnp.squeeze(m_star, axis=axis), l_star


def combine_partial_stats(o: jax.Array, m: jax.Array, l: jax.Array,
                          axis: int = 0) -> jax.Array:
    """Merge shard statistics and apply the deferred normalization.

    Returns the attention output ``o* / max(l*, 1e-30)`` in float32 — equal
    to running the sequential flash walk over the concatenated shards."""
    o_star, _, l_star = merge_partial_stats(o, m, l, axis=axis)
    return o_star / jnp.maximum(l_star, _EPS)[..., None]
