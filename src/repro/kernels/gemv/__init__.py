from repro.kernels.gemv.ops import gemv_int8  # noqa: F401
