"""jit'd public wrapper: dynamic per-row activation quantization (W8A8) +
platform dispatch (Pallas on TPU, oracle elsewhere / when interpreting)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gemv.gemv import gemv_int8_pallas
from repro.kernels.gemv.ref import gemv_int8_ref
from repro.quant.int8 import QuantizedTensor, quantize_int8


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret",
                                             "out_dtype"))
def gemv_int8(x: jax.Array, w: QuantizedTensor, *, use_pallas: bool = None,
              interpret: bool = False, out_dtype=jnp.bfloat16) -> jax.Array:
    """x: (..., K) float; w: QuantizedTensor (K,N) int8 + (1,N) scale."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    lead = x.shape[:-1]
    K = x.shape[-1]
    xf = x.reshape(-1, K)
    xq = quantize_int8(xf, axis=-1)
    ws = w.scale.reshape(1, -1)
    if use_pallas or interpret:
        out = gemv_int8_pallas(xq.values, xq.scale, w.values, ws,
                               interpret=interpret or not _on_tpu())
    else:
        out = gemv_int8_ref(xq.values, xq.scale, w.values, ws)
    return out.reshape(*lead, -1).astype(out_dtype)
