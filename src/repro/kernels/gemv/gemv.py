"""INT8 weight-stationary GEMV / thin-matmul Pallas TPU kernel.

TPU adaptation of the paper's cache-resident GEMV (§4.2):
- the (B,K) activation block is *pinned* in VMEM across the whole N/K grid —
  the analogue of the per-core L1-resident activation copy;
- (K_blk, N_blk) INT8 weight tiles stream HBM→VMEM exactly once — the
  analogue of LLC-streamed weight shards ("data cross the LLC–core boundary
  as few times as possible");
- int8×int8→int32 MXU dot (the VNNI analogue), f32 accumulation across the
  K grid dimension in the revisited output block.

Grid: (n_N, n_K) — K innermost so each output tile accumulates in place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xq_ref, xs_ref, wq_ref, ws_ref, o_ref, *, n_k: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jax.lax.dot_general(
        xq_ref[...], wq_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    o_ref[...] += acc.astype(jnp.float32)

    @pl.when(k == n_k - 1)
    def _scale():
        o_ref[...] *= xs_ref[...] * ws_ref[...]


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def gemv_int8_pallas(xq: jax.Array, x_scale: jax.Array, wq: jax.Array,
                     w_scale: jax.Array, *, block_n: int = 256,
                     block_k: int = 512, interpret: bool = False) -> jax.Array:
    """xq: (B,K) int8; x_scale: (B,1) f32; wq: (K,N) int8; w_scale: (1,N) f32.
    Returns (B,N) f32. Block sizes MXU-aligned (multiples of 128)."""
    B, K = xq.shape
    N = wq.shape[1]
    bn, bk = min(block_n, N), min(block_k, K)
    assert K % bk == 0 and N % bn == 0, (K, bk, N, bn)
    n_n, n_k = N // bn, K // bk
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(n_n, n_k),
        in_specs=[
            pl.BlockSpec((B, bk), lambda n, k: (0, k)),       # act: VMEM-pinned rows
            pl.BlockSpec((B, 1), lambda n, k: (0, 0)),        # act row scales
            pl.BlockSpec((bk, bn), lambda n, k: (k, n)),      # weight tile stream
            pl.BlockSpec((1, bn), lambda n, k: (0, n)),       # w channel scales
        ],
        out_specs=pl.BlockSpec((B, bn), lambda n, k: (0, n)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=interpret,
    )(xq, x_scale, wq, w_scale)
