"""Pure-jnp oracle for the INT8 weight-stationary GEMV kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gemv_int8_ref(xq: jax.Array, x_scale: jax.Array, wq: jax.Array,
                  w_scale: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    """xq: (B,K) int8 row-quantized activations with x_scale (B,1) f32;
    wq: (K,N) int8 with per-output-channel w_scale (1,N) f32 → (B,N)."""
    acc = jax.lax.dot_general(
        xq, wq, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(out_dtype)
