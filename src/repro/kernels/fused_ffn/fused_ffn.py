"""Fused gated-FFN (SwiGLU/GeGLU) Pallas TPU kernel.

The paper's Fig 6(b): after the bounded fan-in merge of the residual stream,
execute "fused GEMV and elementwise operations" such that weight tiles stream
exactly once. Here all three weight streams (gate, up, down) for one F-block
are touched once per kernel step; the gated intermediate h = act(x·Wg)⊙(x·Wu)
lives only in VMEM (never round-trips to HBM — the LLC-traffic argument of
§4.2 mapped to the HBM boundary); the output accumulates across F-blocks in
the revisited (B,D) block.

Grid: (n_F,). Activation block (B,D) VMEM-pinned for the whole walk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, *, act: str):
    f = pl.program_id(0)

    @pl.when(f == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                   # (B, D) pinned
    g = jax.lax.dot_general(x, wg_ref[...].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(x, wu_ref[...].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)) * u
    o_ref[...] += jax.lax.dot_general(h, wd_ref[...].astype(jnp.float32),
                                      (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_f", "act", "interpret"))
def fused_ffn_pallas(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                     w_down: jax.Array, *, block_f: int = 512,
                     act: str = "silu", interpret: bool = False) -> jax.Array:
    """x: (B,D); w_gate/w_up: (D,F); w_down: (F,D) → (B,D) f32."""
    B, D = x.shape
    F = w_gate.shape[1]
    bf = min(block_f, F)
    assert F % bf == 0, (F, bf)
    return pl.pallas_call(
        functools.partial(_kernel, act=act),
        grid=(F // bf,),
        in_specs=[
            pl.BlockSpec((B, D), lambda f: (0, 0)),       # pinned activations
            pl.BlockSpec((D, bf), lambda f: (0, f)),      # gate tile stream
            pl.BlockSpec((D, bf), lambda f: (0, f)),      # up tile stream
            pl.BlockSpec((bf, D), lambda f: (f, 0)),      # down tile stream
        ],
        out_specs=pl.BlockSpec((B, D), lambda f: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
