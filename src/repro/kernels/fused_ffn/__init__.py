from repro.kernels.fused_ffn.ops import fused_ffn  # noqa: F401
