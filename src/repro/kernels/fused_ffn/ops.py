"""jit'd wrapper with platform dispatch for the fused gated FFN."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fused_ffn.fused_ffn import fused_ffn_pallas
from repro.kernels.fused_ffn.ref import fused_ffn_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("act", "use_pallas", "interpret",
                                             "block_f", "out_dtype"))
def fused_ffn(x, w_gate, w_up, w_down, *, act: str = "silu",
              use_pallas: bool = None, interpret: bool = False,
              block_f: int = 512, out_dtype=jnp.bfloat16) -> jax.Array:
    """x: (..., D) → (..., D)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    if use_pallas or interpret:
        out = fused_ffn_pallas(xf, w_gate, w_up, w_down, act=act,
                               block_f=block_f,
                               interpret=interpret or not _on_tpu())
    else:
        out = fused_ffn_ref(xf, w_gate, w_up, w_down, act=act)
    return out.reshape(*lead, -1).astype(out_dtype)
