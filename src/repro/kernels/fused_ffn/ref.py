"""Pure-jnp oracle for the fused gated-FFN kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_ffn_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                  w_down: jax.Array, act: str = "silu") -> jax.Array:
    """x: (B,D); w_gate/w_up: (D,F); w_down: (F,D) → (B,D) f32."""
    xf = x.astype(jnp.float32)
    g = xf @ w_gate.astype(jnp.float32)
    u = xf @ w_up.astype(jnp.float32)
    fn = jax.nn.silu if act == "silu" else jax.nn.gelu
    return (fn(g) * u) @ w_down.astype(jnp.float32)
