"""Pallas TPU kernels for the paper's compute hot-spots (§4.2):

- gemv:         cache-resident INT8 weight-stationary GEMV / thin matmul
                (LLC-streamed weights → HBM→VMEM BlockSpec streaming;
                 L1-pinned activation → VMEM-pinned activation block)
- flash_decode: Flash-style decode attention over the contiguous KV cache
                (KV streamed in tiles, online softmax, GQA, INT8 KV)
- fused_ffn:    gated-FFN fusion — both GEMVs + elementwise in one kernel so
                weight tiles are streamed exactly once (paper Fig 6b)

Each package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd wrapper
with platform dispatch), ref.py (pure-jnp oracle used by tests and by the CPU
dry-run path).
"""
